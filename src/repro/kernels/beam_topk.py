"""Pallas TPU kernel: fused beam phase 2 (generator-pool priority search).

The paper's top-k priority search (engine/beam.py): every locus becomes a
lazy generator over its score-sorted emission list; each step pops the
best P emissions across all generators, folds leaves into the result heap,
re-arms popped generators, and keeps the best W of the merged pool by
admissible bound.  The pure-jnp path runs this as a vmapped data-dependent
``lax.while_loop`` whose every step is a chain of XLA ``top_k`` / gather /
scatter ops; this kernel keeps the whole per-query search state resident
in VMEM scratch for the entire search:

- the ``(W,)`` generator pool — node ids ``gn``, emission cursors ``gc``,
  admissible bounds ``gb``;
- the ``(k,)`` result heap (``ls`` scores / ``li`` string ids);
- the ``dropped_max`` exactness tracker (max bound ever dropped by the
  width-bounded pool — the admissible-bound exactness guard of the paper's
  §2.2 retry).

The data-dependent while_loop becomes a **masked fixed-trip loop** bounded
by the static ``max_steps``: ``lax.fori_loop`` runs exactly ``max_steps``
trips and every state write is predicated on the per-query ``active``
flag (the reference loop's own continuation condition), so rows that
finish early freeze bit-exactly where the while_loop would have stopped
them.  Each P-wide ``lax.top_k`` pop — and the k-wide leaf merge and
W-wide pool re-selection — is replaced by an in-kernel **bitonic
selection network**: one lexicographic sort over (bound desc, column
asc) pairs, which reproduces ``lax.top_k`` ordering exactly
(score-descending, ties to the lower index) and lowers to a single
bitonic network on the VPU instead of ``top_k``'s gather/scatter chain.

The search body is written once against a small emission-table accessor
seam and runs in two tiers:

- *resident* (``beam_topk_batch``): the emission tables (``emit_ptr`` /
  ``emit_node`` / ``emit_score`` / ``emit_is_leaf``) and ``leaf_sid``
  are VMEM-resident like the trie-walk kernel's CSRs;
- *streamed* (``beam_topk_batch_streamed``): the tables stay in HBM and
  each step's pointer pairs, emission-row windows and sid gathers are
  double-buffered into VMEM scratch via ``make_async_copy``
  (:mod:`repro.kernels.stream`).  The tile-aligned layout
  (``trie_build.pack_stream_tiles``) guarantees one ``emit_tile`` window
  covers any node's whole emission row, so reading the cursor slot off
  the streamed row yields exactly the resident gather's value — both
  tiers are bit-identical to ``jax.vmap(engine.beam.beam_topk)``
  (scores, string ids AND the per-query ``exact`` flags); the substrate
  parity suite enforces this in interpret mode on CPU.

``PallasSubstrate.can_beam_batch`` probes the static sizes (W, P, k,
max_steps) and picks the tier by comparing the emission-table bytes
against the VMEM budget; shapes outside the envelope fall back to the
vmapped jnp reference.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.stream import StreamTable, row_take

# plain python int: jnp scalars would be captured as constants by the
# pallas kernel tracer
_NEG_ONE = -1


def _topk_sorted(vals, n: int, payloads):
    """``lax.top_k(vals, n)`` with payloads, as one bitonic selection
    network over [BQ, C].

    A single lexicographic sort on the key pair (-value, column index) —
    ascending on the negated value = descending on the value, with ties
    resolved toward the lower column index — reproduces ``lax.top_k``
    ordering exactly.  Returns (top_vals [BQ, n],
    top_idx [BQ, n], [top_payload [BQ, n], ...], residue_vals
    [BQ, C-n]): the residue is the sorted tail of *unselected* values
    (the pool re-selection reads the dropped bounds off it).  Values must
    stay above INT32_MIN (scores are >= -1 here), so the key negation
    cannot overflow.
    """
    bq, c = vals.shape
    idx = jax.lax.broadcasted_iota(jnp.int32, (bq, c), 1)
    out = jax.lax.sort((-vals, idx) + tuple(payloads), dimension=1,
                       num_keys=2, is_stable=False)
    svals = -out[0]
    return (svals[:, :n], out[1][:, :n],
            [p[:, :n] for p in out[2:]], svals[:, n:])


# ---------------------------------------------------------------------------
# emission-table accessor seam: the search body is tier-agnostic
# ---------------------------------------------------------------------------


class _ResidentEmit:
    """VMEM-resident emission-table reads (the original kernel's forms)."""

    def __init__(self, eptr, enode, escore, eleaf, lsid):
        self.eptr, self.enode = eptr, enode
        self.escore, self.eleaf, self.lsid_arr = escore, eleaf, lsid
        self.e_size = max(int(enode.shape[0]), 1)

    def emit_bound(self, nodes, cursors):
        """Admissible bound of each generator's current emission; -1 when
        the node is dead or the cursor ran off its emission list."""
        valid = nodes >= 0
        n = jnp.where(valid, nodes, 0)
        e = jnp.take(self.eptr, n) + cursors
        ok = valid & (e < jnp.take(self.eptr, n + 1))
        score = jnp.take(self.escore, jnp.clip(e, 0, self.e_size - 1))
        return jnp.where(ok, score, _NEG_ONE)

    def pop_emissions(self, nodes, cursors):
        """(node, score, is_leaf) of each generator's current emission
        (callers mask invalid lanes; a popped lane's cursor is in-row)."""
        e = jnp.take(self.eptr, nodes) + cursors
        e = jnp.clip(e, 0, self.e_size - 1)
        return (jnp.take(self.enode, e), jnp.take(self.escore, e),
                jnp.take(self.eleaf, e) != 0)

    def lsid(self, nodes):
        return jnp.take(self.lsid_arr, nodes)


class _StreamedEmit:
    """HBM-resident emission tables behind double-buffered windowed DMA.

    Pointer pairs stream per lane; emission rows stream as whole
    ``emit_tile`` windows (the tile covers the longest row) with the
    cursor slot read row-locally — the same value the resident gather
    reads at ``eptr[n] + cursor``.
    """

    def __init__(self, eptr_t, enode_t, escore_t, eleaf_t, lsid_t,
                 tile: int):
        self.eptr_t, self.enode_t = eptr_t, enode_t
        self.escore_t, self.eleaf_t, self.lsid_t = escore_t, eleaf_t, lsid_t
        self.tile = tile

    def emit_bound(self, nodes, cursors):
        valid = nodes >= 0
        n = jnp.where(valid, nodes, 0)
        lo, hi = self.eptr_t.pairs(n)
        ok = valid & (lo + cursors < hi)
        win = self.escore_t.windows(lo)
        cur = jnp.clip(cursors, 0, self.tile - 1)
        score = row_take(win, cur[..., None])[..., 0]
        return jnp.where(ok, score, _NEG_ONE)

    def pop_emissions(self, nodes, cursors):
        lo, _ = self.eptr_t.pairs(nodes)
        cur = jnp.clip(cursors, 0, self.tile - 1)
        node = row_take(self.enode_t.windows(lo), cur[..., None])[..., 0]
        score = row_take(self.escore_t.windows(lo), cur[..., None])[..., 0]
        leaf = row_take(self.eleaf_t.windows(lo), cur[..., None])[..., 0]
        return node, score, leaf != 0

    def lsid(self, nodes):
        return self.lsid_t.gather(nodes)


def _pk_iters(size: int) -> int:
    """Fixed trip count that lets a binary search converge over a
    ``size``-entry sorted table."""
    return max(1, int(math.ceil(math.log2(max(size, 1) + 1))))


def _pk_rank(ids, nodes, iters: int):
    """Sorted-id-table rank (clipped) + exact flag; mirrors
    ``engine.packed._rank`` as a fixed-trip binary search."""
    size = int(ids.shape[0])
    lo = jnp.zeros_like(nodes)
    hi = jnp.full_like(nodes, size)
    for _ in range(iters):
        cont = lo < hi
        mid = (lo + hi) >> 1
        v = jnp.take(ids, jnp.clip(mid, 0, max(size, 1) - 1))
        go_right = v < nodes
        lo = jnp.where(cont & go_right, mid + 1, lo)
        hi = jnp.where(cont & ~go_right, mid, hi)
    rc = jnp.clip(lo, 0, max(size, 1) - 1)
    return rc, (lo < size) & (jnp.take(ids, rc) == nodes)


class _PackedEmit:
    """Compressed-layout emission reads: stored nodes (``c_ids`` rows)
    read their compacted emission lists; an unstored (unary non-terminal
    dict) node's list is exactly ``[(v+1, max_score, not-leaf)]``, read
    off its chain representative — the same forms as
    :mod:`repro.core.engine.packed`.  Narrow (u8/u16) values widen to
    i32 at the read."""

    _IS_SYN = 4   # p_flags bit (mirror engine.packed)

    def __init__(self, flags, c_ids, eptr, enode, escore, eleaf,
                 maxscore, l_ids, l_sid):
        self.flags, self.c_ids, self.eptr = flags, c_ids, eptr
        self.enode, self.escore, self.eleaf = enode, escore, eleaf
        self.maxscore, self.l_ids, self.l_sid = maxscore, l_ids, l_sid
        self.e_size = max(int(enode.shape[0]), 1)

    def emit_bound(self, nodes, cursors):
        valid = nodes >= 0
        n = jnp.where(valid, nodes, 0)
        rc, stored = _pk_rank(self.c_ids, n,
                              _pk_iters(int(self.c_ids.shape[0])))
        e = jnp.take(self.eptr, rc) + cursors
        ok_s = stored & (e < jnp.take(self.eptr, rc + 1))
        sc_s = jnp.take(self.escore,
                        jnp.clip(e, 0, self.e_size - 1)).astype(jnp.int32)
        fl = jnp.take(self.flags, n).astype(jnp.int32)
        derived = ~stored & ((fl & self._IS_SYN) == 0) & (cursors == 0)
        ms = jnp.take(self.maxscore, rc).astype(jnp.int32)
        bound = jnp.where(ok_s, sc_s, jnp.where(derived, ms, _NEG_ONE))
        return jnp.where(valid, bound, _NEG_ONE)

    def pop_emissions(self, nodes, cursors):
        rc, stored = _pk_rank(self.c_ids, nodes,
                              _pk_iters(int(self.c_ids.shape[0])))
        e = jnp.clip(jnp.take(self.eptr, rc) + cursors, 0, self.e_size - 1)
        ms = jnp.take(self.maxscore, rc).astype(jnp.int32)
        node = jnp.where(stored, jnp.take(self.enode, e), nodes + 1)
        score = jnp.where(stored,
                          jnp.take(self.escore, e).astype(jnp.int32), ms)
        leaf = jnp.where(stored, jnp.take(self.eleaf, e) != 0, False)
        return node, score, leaf

    def lsid(self, nodes):
        size = max(int(self.l_ids.shape[0]), 1)
        rc, _ = _pk_rank(self.l_ids, nodes,
                         _pk_iters(int(self.l_ids.shape[0])))
        return jnp.take(self.l_sid,
                        jnp.clip(rc, 0, size - 1)).astype(jnp.int32)


def _search(tabs, loci,
            os_ref, oi_ref, oe_ref,
            gn_ref, gc_ref, gb_ref, ls_ref, li_ref, dm_ref, *,
            gens: int, expand: int, k: int, max_steps: int):
    """The generator-pool priority search, written once against the
    accessor seam; ``tabs`` is resident or streamed."""
    bq, f = loci.shape
    W, P = gens, expand

    # pool seeded with the locus antichain (reference: dynamic_update_slice
    # of loci into a -1-filled (W,) pool; the probe guarantees F <= W)
    gn = jnp.concatenate(
        [loci, jnp.full((bq, W - f), _NEG_ONE, jnp.int32)], axis=1) \
        if W > f else loci[:, :W]
    gc = jnp.zeros((bq, W), jnp.int32)
    gb = tabs.emit_bound(gn, gc)
    gn_ref[...] = jnp.where(gb >= 0, gn, _NEG_ONE)
    gc_ref[...] = gc
    gb_ref[...] = gb
    ls_ref[...] = jnp.full((bq, k), _NEG_ONE, jnp.int32)
    li_ref[...] = jnp.full((bq, k), _NEG_ONE, jnp.int32)
    dm_ref[...] = jnp.full((bq,), _NEG_ONE, jnp.int32)

    iota_w = jax.lax.broadcasted_iota(jnp.int32, (bq, W), 1)

    def step(_, carry):
        gn, gc, gb = gn_ref[...], gc_ref[...], gb_ref[...]
        ls, li, dm = ls_ref[...], li_ref[...], dm_ref[...]
        best = jnp.max(gb, axis=1)
        kth = ls[:, k - 1]
        # the reference while_loop's continuation condition, per query
        active = (best >= 0) & (kth < best)

        # pop the best P emissions across all generators
        topb, topi, _, _ = _topk_sorted(gb, P, ())
        sel_valid = topb >= 0
        sel_n = jnp.where(sel_valid, row_take(gn, topi), 0)
        em_node, em_score, em_leaf = tabs.pop_emissions(
            sel_n, row_take(gc, topi))

        # leaves -> result heap (k-round merge of heap + new leaves; heap
        # entries sit at lower indices, so ties keep the incumbent)
        leaf_ok = sel_valid & em_leaf
        new_ls = jnp.where(leaf_ok, em_score, _NEG_ONE)
        new_li = jnp.where(
            leaf_ok, tabs.lsid(jnp.where(leaf_ok, em_node, 0)), _NEG_ONE)
        ls2, _, (li2,), _ = _topk_sorted(
            jnp.concatenate([ls, new_ls], axis=1), k,
            (jnp.concatenate([li, new_li], axis=1),))

        # internal emissions -> new generators
        int_ok = sel_valid & ~em_leaf
        new_n = jnp.where(int_ok, em_node, _NEG_ONE)
        new_c = jnp.zeros((bq, P), jnp.int32)
        new_b = tabs.emit_bound(new_n, new_c)
        new_n = jnp.where(new_b >= 0, new_n, _NEG_ONE)

        # advance popped generators (one-hot scatter: topi rows are
        # distinct positions, so the sum is the reference's .at[].add)
        hit = (topi[:, :, None] == iota_w[:, None, :]) \
            & sel_valid[:, :, None]
        gc2 = gc + hit.sum(axis=1).astype(jnp.int32)
        gb2 = tabs.emit_bound(gn, gc2)
        gn2 = jnp.where(gb2 >= 0, gn, _NEG_ONE)

        # merge pools, keep top-W by bound; the sorted residue holds the
        # dropped bounds for the exactness tracker
        pool_n = jnp.concatenate([gn2, new_n], axis=1)
        pool_c = jnp.concatenate([gc2, new_c], axis=1)
        pool_b = jnp.concatenate([gb2, new_b], axis=1)
        keep_b, _, (keep_n, keep_c), residue = _topk_sorted(
            pool_b, W, (pool_n, pool_c))
        drop_best = jnp.max(jnp.maximum(residue, _NEG_ONE), axis=1)
        dm2 = jnp.maximum(dm, drop_best)

        m = active[:, None]
        gn_ref[...] = jnp.where(m, keep_n, gn)
        gc_ref[...] = jnp.where(m, keep_c, gc)
        gb_ref[...] = jnp.where(m, keep_b, gb)
        ls_ref[...] = jnp.where(m, ls2, ls)
        li_ref[...] = jnp.where(m, li2, li)
        dm_ref[...] = jnp.where(active, dm2, dm)
        return carry

    jax.lax.fori_loop(0, max_steps, step, 0)

    gb, ls, dm = gb_ref[...], ls_ref[...], dm_ref[...]
    best = jnp.max(gb, axis=1)
    kth = ls[:, k - 1]
    finished = ~((best >= 0) & (kth < best))
    # strict admissible bound: only a dropped candidate strictly above the
    # k-th score threatens exactness — an equal-bound drop ties at best
    # and must NOT trigger the doubled-width retry
    exact = (dm <= kth) & finished
    os_ref[...] = ls
    oi_ref[...] = li_ref[...]
    oe_ref[...] = exact.astype(jnp.int32)


def _kernel(eptr_ref, enode_ref, escore_ref, eleaf_ref, lsid_ref,
            loci_ref,
            os_ref, oi_ref, oe_ref,
            gn_ref, gc_ref, gb_ref, ls_ref, li_ref, dm_ref, **statics):
    tabs = _ResidentEmit(eptr_ref[...], enode_ref[...], escore_ref[...],
                         eleaf_ref[...], lsid_ref[...])
    _search(tabs, loci_ref[...], os_ref, oi_ref, oe_ref,
            gn_ref, gc_ref, gb_ref, ls_ref, li_ref, dm_ref, **statics)


def _kernel_packed(flg_ref, c_ids_ref, eptr_ref, enode_ref, escore_ref,
                   eleaf_ref, ms_ref, l_ids_ref, lsid_ref,
                   loci_ref,
                   os_ref, oi_ref, oe_ref,
                   gn_ref, gc_ref, gb_ref, ls_ref, li_ref, dm_ref,
                   **statics):
    tabs = _PackedEmit(flg_ref[...], c_ids_ref[...], eptr_ref[...],
                       enode_ref[...], escore_ref[...], eleaf_ref[...],
                       ms_ref[...], l_ids_ref[...], lsid_ref[...])
    _search(tabs, loci_ref[...], os_ref, oi_ref, oe_ref,
            gn_ref, gc_ref, gb_ref, ls_ref, li_ref, dm_ref, **statics)


def _kernel_streamed(eptr_hbm, enode_hbm, escore_hbm, eleaf_hbm, lsid_hbm,
                     loci_ref,
                     os_ref, oi_ref, oe_ref,
                     gn_ref, gc_ref, gb_ref, ls_ref, li_ref, dm_ref,
                     pair_buf, row_buf, word_buf, sem_p, sem_r, sem_w, *,
                     emit_tile: int, **statics):
    tabs = _StreamedEmit(
        StreamTable(eptr_hbm, pair_buf, sem_p, 2),
        StreamTable(enode_hbm, row_buf, sem_r, emit_tile),
        StreamTable(escore_hbm, row_buf, sem_r, emit_tile),
        StreamTable(eleaf_hbm, row_buf, sem_r, emit_tile),
        StreamTable(lsid_hbm, word_buf, sem_w, 1),
        emit_tile)
    _search(tabs, loci_ref[...], os_ref, oi_ref, oe_ref,
            gn_ref, gc_ref, gb_ref, ls_ref, li_ref, dm_ref, **statics)


def _call(kernel, tables, table_specs, loci, scratch, *, k: int,
          gens: int, block_b: int, interpret: bool):
    bsz, f = loci.shape
    grid = (bsz // block_b,)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=table_specs + [
            pl.BlockSpec((block_b, f), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, k), lambda i: (i, 0)),
            pl.BlockSpec((block_b, k), lambda i: (i, 0)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, k), jnp.int32),
            jax.ShapeDtypeStruct((bsz, k), jnp.int32),
            jax.ShapeDtypeStruct((bsz,), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_b, gens), jnp.int32),   # gn: generator nodes
            pltpu.VMEM((block_b, gens), jnp.int32),   # gc: emission cursors
            pltpu.VMEM((block_b, gens), jnp.int32),   # gb: admissible bounds
            pltpu.VMEM((block_b, k), jnp.int32),      # ls: result scores
            pltpu.VMEM((block_b, k), jnp.int32),      # li: result sids
            pltpu.VMEM((block_b,), jnp.int32),        # dropped_max tracker
        ] + scratch,
        interpret=interpret,
    )(*tables, loci)


@functools.partial(jax.jit, static_argnames=(
    "gens", "expand", "k", "max_steps", "block_b", "interpret"))
def beam_topk_batch(emit_ptr, emit_node, emit_score, emit_is_leaf, leaf_sid,
                    loci, *, gens: int, expand: int, k: int, max_steps: int,
                    block_b: int = 8, interpret: bool = True):
    """Fused beam phase 2 over a locus batch (VMEM-resident tables).

    loci int32[B, F] (-1 padded locus antichains, B divisible by block_b;
    the wrapper in ops.py pads — all-(-1) rows yield -1 results with
    exact=1).  Tables are the DeviceTrie emission arrays (``emit_is_leaf``
    as int32) and ``leaf_sid``; ``emit_node`` must be non-empty (the
    degenerate empty dictionary short-circuits in ops.py, mirroring the
    reference).  Returns (scores[B, k], sids[B, k], exact[B] int32 0/1) —
    bit-identical to ``jax.vmap(engine.beam.beam_topk)`` on the jnp
    substrate.
    """
    def full(a):
        shape = tuple(int(s) for s in a.shape)
        return pl.BlockSpec(shape, (lambda i: (0,) * len(shape)))

    kernel = functools.partial(_kernel, gens=gens, expand=expand, k=k,
                               max_steps=max_steps)
    tables = [emit_ptr, emit_node, emit_score, emit_is_leaf, leaf_sid]
    return _call(kernel, tables, [full(a) for a in tables], loci, [],
                 k=k, gens=gens, block_b=block_b, interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "gens", "expand", "k", "max_steps", "block_b", "interpret"))
def beam_topk_batch_packed(p_flags, c_ids, c_eptr, c_enode, c_escore,
                           c_eleaf, c_maxscore, l_ids, l_sid, loci, *,
                           gens: int, expand: int, k: int, max_steps: int,
                           block_b: int = 8, interpret: bool = True):
    """Compressed-layout variant of :func:`beam_topk_batch`: same
    contract and bit-identical results, reading the packed emission store
    (u8 flags, sorted ``c_ids`` side tables, u16-or-i32 scores/sids)
    VMEM-resident.  ``c_enode`` must be non-empty (the degenerate empty
    dictionary short-circuits in ops.py, like the uncompressed path)."""
    def full(a):
        shape = tuple(int(s) for s in a.shape)
        return pl.BlockSpec(shape, (lambda i: (0,) * len(shape)))

    kernel = functools.partial(_kernel_packed, gens=gens, expand=expand,
                               k=k, max_steps=max_steps)
    tables = [p_flags, c_ids, c_eptr, c_enode, c_escore, c_eleaf,
              c_maxscore, l_ids, l_sid]
    return _call(kernel, tables, [full(a) for a in tables], loci, [],
                 k=k, gens=gens, block_b=block_b, interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "gens", "expand", "k", "max_steps", "emit_tile", "block_b", "interpret"))
def beam_topk_batch_streamed(emit_ptr, emit_node, emit_score, emit_is_leaf,
                             leaf_sid, loci, *, gens: int, expand: int,
                             k: int, max_steps: int, emit_tile: int,
                             block_b: int = 4, interpret: bool = True):
    """HBM-resident variant of :func:`beam_topk_batch`: same contract,
    same results, but the emission tables stay in HBM and every step's
    pointer pairs / emission-row windows / sid gathers are
    double-buffered windowed DMAs.  ``emit_tile`` is the static window
    width from the tile-aligned layout (``EngineConfig.emit_tile``)."""
    hbm = pl.BlockSpec(memory_space=pltpu.ANY)
    kernel = functools.partial(_kernel_streamed, gens=gens, expand=expand,
                               k=k, max_steps=max_steps,
                               emit_tile=emit_tile)
    tables = [emit_ptr, emit_node, emit_score, emit_is_leaf, leaf_sid]
    lanes = block_b * gens
    scratch = [
        pltpu.VMEM((lanes, 2), jnp.int32),            # pointer-pair stage
        pltpu.VMEM((lanes, emit_tile), jnp.int32),    # emission-row windows
        pltpu.VMEM((lanes, 1), jnp.int32),            # sid gathers
        pltpu.SemaphoreType.DMA((2,)),
        pltpu.SemaphoreType.DMA((2,)),
        pltpu.SemaphoreType.DMA((2,)),
    ]
    return _call(kernel, tables, [hbm] * 5, loci, scratch,
                 k=k, gens=gens, block_b=block_b, interpret=interpret)
