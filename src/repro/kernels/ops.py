"""Jit'd public wrappers around the Pallas kernels.

Handle padding to block multiples, ragged->dense conversion, interpret-mode
selection (interpret=True on CPU so the kernel bodies execute in Python;
compiled lowering on TPU), and fall-through to the pure-jnp references when
that is the right call (e.g. degenerate shapes).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.candidate_topk import candidate_topk as _candidate_topk
from repro.kernels.embedding_bag import embedding_bag_dense as _embedding_bag
from repro.kernels.locus_merge import locus_topk_merge as _locus_topk_merge
from repro.kernels.topk_select import topk_select as _topk_select
from repro.kernels.trie_walk import trie_walk as _trie_walk


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_rows(x, mult, fill):
    """Pad axis 0 of ``x`` up to a multiple of ``mult`` with ``fill``.

    Returns (padded, original_row_count).  Callers slice the kernel output
    back to the original count; the fill value must make padded rows
    inert for the kernel at hand (see ``_pad_query_batch``).
    """
    b = x.shape[0]
    pad = (-b) % mult
    if pad == 0:
        return x, b
    widths = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, widths, constant_values=fill), b


def _pad_query_batch(queries, qlens, mult):
    """Pad a (queries, qlens) batch together to a multiple of ``mult`` rows.

    Invariant: a padded row must walk to the root with depth 0 so it can
    be sliced off without a trace.  Two independent guards enforce it —
    chars fill with -1 (never matches an edge) AND qlens fill with 0 (the
    walk is inactive from step 0) — so a future change to either fill
    value alone stays safe.  Checked here on concrete (non-traced) calls.
    """
    q, b = _pad_rows(queries, mult, -1)
    ql, b2 = _pad_rows(qlens, mult, 0)
    assert b == b2, "queries and qlens disagree on batch size"
    if b < q.shape[0] and not isinstance(q, jax.core.Tracer):
        assert (np.asarray(q[b:]) < 0).all() and \
            (np.asarray(ql[b:]) == 0).all(), \
            "padded query rows must walk to the root with depth 0"
    return q, ql, b


def trie_walk(first_child, edge_char, edge_child, queries, qlens,
              block_q: int = 128, streamed: bool = False,
              walk_tile: int | None = None):
    """Batched longest-prefix walk; see kernels/trie_walk.py.

    ``streamed=True`` runs the HBM-resident DMA-streamed variant (same
    results; it uses a smaller default block — each query row streams
    its own windows) and then requires ``walk_tile``, the tile-aligned
    layout's static window width (``EngineConfig.walk_tile``) — a
    narrower window would silently truncate long CSR rows, so there is
    no default.
    """
    if streamed:
        if walk_tile is None:
            raise ValueError(
                "streamed trie_walk requires walk_tile (the layout's "
                "static window width, EngineConfig.walk_tile)")
        block_q = min(8, block_q)
    block_q = min(block_q, max(int(queries.shape[0]), 1))
    q, ql, b = _pad_query_batch(queries, qlens, block_q)
    if streamed and int(edge_char.shape[0]) > 0:
        from repro.kernels.trie_walk import trie_walk_streamed
        node, depth = trie_walk_streamed(
            first_child, edge_char, edge_child, q, ql, tile=walk_tile,
            block_q=block_q, interpret=_interpret())
    else:
        node, depth = _trie_walk(first_child, edge_char, edge_child, q, ql,
                                 block_q=block_q, interpret=_interpret())
    return node[:b], depth[:b]


def _nonempty(a, fill=-1):
    """Pad a 0-row table to one inert row (pallas refs need size >= 1;
    callers gate usage with the matching ``has_*`` static)."""
    if int(a.shape[0]) > 0:
        return a
    return jnp.full((1,) + tuple(a.shape[1:]), fill, a.dtype)


def _pair_ptr(ptr):
    """Pad a CSR pointer table to >= 2 entries so ``ptr[rc + 1]`` stays
    in bounds for the packed kernels' padded single ``-1`` id row (whose
    lookups are discarded — no node id matches -1)."""
    if int(ptr.shape[0]) >= 2:
        return ptr
    return jnp.zeros((2,), ptr.dtype)


def _is_packed(t) -> bool:
    """Compressed-layout probe on a duck-typed DeviceTrie (mirrors
    ``engine.packed.is_packed`` without importing the engine)."""
    return getattr(t, "p_labels", None) is not None \
        and int(t.p_labels.shape[0]) > 0


def locus_walk(t, cfg, queries, qlens, block_q: int = 8,
               streamed: bool = False):
    """Fused synonym-aware locus DP; see kernels/locus_dp.py.

    t: engine DeviceTrie (duck-typed — only the array fields are read);
    cfg: EngineConfig.  queries int32[B, L] (-1 padded), qlens int32[B].
    Returns (loci[B, F], overflow[B]) matching the jnp reference DP
    bit-for-bit.  ``streamed=True`` keeps the dictionary-sized tables in
    HBM and streams windows per access (same results, smaller block).
    """
    from repro.kernels.locus_dp import locus_dp_walk as _locus_dp
    from repro.kernels.locus_dp import \
        locus_dp_walk_streamed as _locus_dp_streamed

    if streamed:
        block_q = min(4, block_q)
    block_q = min(block_q, max(int(queries.shape[0]), 1))
    q, ql, b = _pad_query_batch(queries, qlens, block_q)
    if _is_packed(t):
        from repro.kernels.locus_dp import (
            locus_dp_walk_packed as _locus_dp_packed,
            locus_dp_walk_packed_streamed as _locus_dp_packed_streamed)

        tables = (
            t.p_labels, t.p_flags, t.c_ids, t.c_tout,
            _nonempty(t.b_ids), _pair_ptr(t.b_ptr),
            _nonempty(t.b_char), _nonempty(t.b_child),
            _nonempty(t.sb_ids), _pair_ptr(t.sb_ptr),
            _nonempty(t.sb_char), _nonempty(t.sb_child),
            _nonempty(t.t_ids), _nonempty(t.t_plane),
            _nonempty(t.la_ids), _pair_ptr(t.la_ptr),
            _nonempty(t.link_rule), _nonempty(t.link_target),
            t.r_first_child, _nonempty(t.r_edge_char),
            _nonempty(t.r_edge_child), t.r_term_plane)
        statics = dict(
            frontier=cfg.frontier, rule_matches=cfg.rule_matches,
            max_lhs_len=cfg.max_lhs_len, max_terms=cfg.max_terms_per_node,
            # syn nodes exist iff teleports do (every expanded branch
            # ends in one) or a non-unary syn row was stored
            has_syn=int(t.t_ids.shape[0]) > 0
            or int(t.sb_child.shape[0]) > 0,
            has_tele=cfg.teleports > 0,
            has_links=int(t.link_rule.shape[0]) > 0,
            edit_budget=cfg.edit_budget, branch_width=cfg.branch_width,
            block_q=block_q, interpret=_interpret())
        fn = _locus_dp_packed_streamed if streamed else _locus_dp_packed
        loci, overflow = fn(*tables, q, ql, **statics)
        return loci[:b], overflow[:b]
    tables = (
        t.first_child, t.edge_char, t.edge_child,
        t.s_first_child, _nonempty(t.s_edge_char), _nonempty(t.s_edge_child),
        t.syn_mask.astype(jnp.int32), t.tout, t.tele_plane,
        t.link_ptr, _nonempty(t.link_rule), _nonempty(t.link_target),
        t.r_first_child, _nonempty(t.r_edge_char), _nonempty(t.r_edge_child),
        t.r_term_plane)
    statics = dict(
        frontier=cfg.frontier, rule_matches=cfg.rule_matches,
        max_lhs_len=cfg.max_lhs_len, max_terms=cfg.max_terms_per_node,
        has_syn=int(t.s_edge_char.shape[0]) > 0,
        has_tele=cfg.teleports > 0,
        has_links=int(t.link_rule.shape[0]) > 0,
        edit_budget=cfg.edit_budget, branch_width=cfg.branch_width,
        block_q=block_q, interpret=_interpret())
    if streamed:
        loci, overflow = _locus_dp_streamed(
            *tables, q, ql, walk_tile=cfg.walk_tile,
            link_tile=cfg.link_tile, **statics)
    else:
        loci, overflow = _locus_dp(*tables, q, ql, **statics)
    return loci[:b], overflow[:b]


def beam_topk(t, cfg, loci, k: int, block_b: int = 8,
              streamed: bool = False):
    """Fused beam phase 2; see kernels/beam_topk.py.

    t: engine DeviceTrie (duck-typed — only the emission arrays and
    ``leaf_sid`` are read); cfg: EngineConfig (``gens``/``expand``/
    ``max_steps`` become the kernel's static trip counts).
    loci int32[B, F] (-1 padded locus antichains).
    Returns (scores[B, k], sids[B, k], exact[B] bool) matching
    ``jax.vmap(engine.beam.beam_topk)`` bit-for-bit.  ``streamed=True``
    keeps the emission tables in HBM and streams row windows per step
    (same results, smaller block).
    """
    from repro.kernels.beam_topk import beam_topk_batch as _beam_topk
    from repro.kernels.beam_topk import \
        beam_topk_batch_streamed as _beam_topk_streamed

    B = int(loci.shape[0])
    packed = _is_packed(t)
    empty = (int(t.c_enode.shape[0]) == 0 if packed
             else int(t.emit_node.shape[0]) == 0)
    if empty:
        # degenerate empty dictionary: mirror the reference's short-circuit
        return (jnp.full((B, k), -1, jnp.int32),
                jnp.full((B, k), -1, jnp.int32),
                jnp.ones((B,), bool))
    if packed:
        if streamed:
            raise ValueError(
                "no streamed packed beam tier — the substrate probe "
                "routes over-budget packed tries to the jnp reference")
        from repro.kernels.beam_topk import \
            beam_topk_batch_packed as _beam_topk_packed

        block_b = min(block_b, max(B, 1))
        l, b = _pad_rows(loci, block_b, -1)
        s, i, e = _beam_topk_packed(
            t.p_flags, t.c_ids, t.c_eptr, t.c_enode, t.c_escore,
            t.c_eleaf, t.c_maxscore, _nonempty(t.l_ids),
            _nonempty(t.l_sid), l, gens=cfg.gens, expand=cfg.expand,
            k=k, max_steps=cfg.max_steps, block_b=block_b,
            interpret=_interpret())
        return s[:b], i[:b], e[:b].astype(bool)
    if streamed:
        block_b = min(4, block_b)
    block_b = min(block_b, max(B, 1))
    # padded rows are all -1 loci => dead pool, -1 results, exact; sliced off
    l, b = _pad_rows(loci, block_b, -1)
    tables = (t.emit_ptr, t.emit_node, t.emit_score,
              t.emit_is_leaf.astype(jnp.int32), t.leaf_sid)
    if streamed:
        s, i, e = _beam_topk_streamed(
            *tables, l, gens=cfg.gens, expand=cfg.expand, k=k,
            max_steps=cfg.max_steps, emit_tile=cfg.emit_tile,
            block_b=block_b, interpret=_interpret())
    else:
        s, i, e = _beam_topk(
            *tables, l, gens=cfg.gens, expand=cfg.expand, k=k,
            max_steps=cfg.max_steps, block_b=block_b,
            interpret=_interpret())
    return s[:b], i[:b], e[:b].astype(bool)


def topk_select(scores, payload, k: int, block_b: int = 8):
    """Fused top-k with payload; see kernels/topk_select.py."""
    if k >= scores.shape[1]:
        return ref.topk_select_ref(scores, payload, k)
    block_b = min(block_b, max(int(scores.shape[0]), 1))
    s, b = _pad_rows(scores, block_b, -(2**31 - 1))
    p, _ = _pad_rows(payload, block_b, -1)
    ts, tp = _topk_select(s, p, k, block_b=block_b, interpret=_interpret())
    return ts[:b], tp[:b]


def cached_topk_merge(loci, topk_score, topk_sid, k: int, block_b: int = 8):
    """Fused cached-top-K locus gather + merge; see kernels/locus_merge.py.

    loci int32[B, F] (-1 padded); topk_score/topk_sid int32[N, K].
    Returns (scores[B, k], sids[B, k]).
    """
    f = int(loci.shape[1])
    kk = int(topk_score.shape[1])
    if k >= f * kk:
        # selection degenerates to sorting the whole (tiny) union
        s, p = ref.cached_topk_merge_ref(loci, topk_score, topk_sid,
                                         min(k, f * kk))
        pad = ((0, 0), (0, k - s.shape[1]))
        return jnp.pad(s, pad, constant_values=-1), \
            jnp.pad(p, pad, constant_values=-1)
    block_b = min(block_b, max(int(loci.shape[0]), 1))
    # padded rows are all -1 loci => every candidate masked empty; sliced off
    l, b = _pad_rows(loci, block_b, -1)
    s, p = _locus_topk_merge(l, topk_score, topk_sid, k, block_b=block_b,
                             interpret=_interpret())
    return s[:b], p[:b]


def cached_topk_merge_packed(t, loci, k: int, block_b: int = 8):
    """Cached merge over the compressed layout's quantized cache.

    Translates each locus to its chain-representative rank in ``c_ids``
    (an unstored unary node's cache row equals its representative's, a
    pack-time invariant) and decodes the u16-or-i32 row planes back to
    raw i32 in-jit, then reuses :func:`cached_topk_merge` unchanged —
    the candidates and their order are exactly the uncompressed path's.
    """
    from repro.core.engine import packed as pk

    valid = loci >= 0
    rc, _ = pk._rank(t.c_ids, jnp.where(valid, loci, 0))
    rloci = jnp.where(valid, rc, -1)
    dec_s = pk.decode_cache_scores(t.pc_score, t.pc_base)
    dec_i = pk.decode_cache_sids(t.pc_sid)
    return cached_topk_merge(rloci, dec_s, dec_i, k, block_b=block_b)


def embedding_bag(table, indices, offsets, weights=None, mode: str = "sum",
                  max_bag: int | None = None, block_b: int = 128):
    """EmbeddingBag over a ragged (indices, offsets) batch.

    indices int32[I] (-1 entries skipped), offsets int32[B+1].
    Densifies to [B, max_bag] then runs the Pallas kernel.
    """
    idx = np.asarray(indices)
    off = np.asarray(offsets)
    bsz = len(off) - 1
    lens = np.diff(off)
    mb = int(max_bag if max_bag is not None else max(int(lens.max(initial=1)), 1))
    dense = np.full((bsz, mb), -1, np.int32)
    wdense = np.zeros((bsz, mb), np.asarray(table).dtype)
    w = np.asarray(weights) if weights is not None else np.ones(len(idx), np.asarray(table).dtype)
    for i in range(bsz):
        n = min(int(lens[i]), mb)
        dense[i, :n] = idx[off[i] : off[i] + n]
        wdense[i, :n] = w[off[i] : off[i] + n]
    return embedding_bag_dense(table, jnp.asarray(dense), jnp.asarray(wdense),
                               mode=mode, block_b=block_b)


def embedding_bag_dense(table, idx, weights, mode: str = "sum",
                        block_b: int = 128):
    """EmbeddingBag on an already-dense [B, MB] index matrix."""
    block_b = min(block_b, max(int(idx.shape[0]), 1))
    idx_p, b = _pad_rows(idx, block_b, -1)
    w_p, _ = _pad_rows(weights, block_b, 0)
    out = _embedding_bag(table, idx_p, w_p, mode=mode, block_b=block_b,
                         interpret=_interpret())
    return out[:b]


def candidate_topk(query, candidates, k: int, block_c: int = 1024):
    """Fused dot scoring + running top-k; see kernels/candidate_topk.py."""
    block_c = min(block_c, max(int(candidates.shape[0]), 1))
    c, n = _pad_rows(candidates, block_c, 0)
    if n < c.shape[0]:
        # padded rows score 0; shift scores by masking is handled by id cut
        pass
    s, i = _candidate_topk(query, c, k, block_c=block_c,
                           interpret=_interpret())
    # drop any padded-row winners (can only appear when k ~ C)
    bad = i >= n
    s = jnp.where(bad, jnp.float32(-3.0e38), s)
    i = jnp.where(bad, -1, i)
    return s, i
