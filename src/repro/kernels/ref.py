"""Pure-jnp oracles for every Pallas kernel (the correctness references).

Each function mirrors the corresponding kernel's contract exactly; kernel
tests sweep shapes/dtypes and assert allclose / exact equality against
these.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def trie_walk_ref(first_child, edge_char, edge_child, queries, qlens):
    """Longest-prefix walk of each query through the CSR trie.

    queries: int32[B, L] (-1 padded); qlens: int32[B].
    Returns (node[B] deepest locus, depth[B] matched chars).
    """
    E = edge_char.shape[0]

    def one(q, ql):
        def step(i, carry):
            node, matched = carry
            c = q[i]
            lo = first_child[node]
            hi = first_child[node + 1]
            # linear scan is fine for a reference; binary search in kernel
            idx = jnp.searchsorted(edge_char, c) if False else None
            span = jnp.arange(E)
            hit = (span >= lo) & (span < hi) & (edge_char == c)
            found = hit.any() & (i < ql) & (c >= 0) & (matched == i)
            child = jnp.where(hit, edge_child, 0).sum()
            node = jnp.where(found, child, node)
            matched = jnp.where(found, matched + 1, matched)
            return node, matched

        node, matched = jax.lax.fori_loop(0, q.shape[0], step,
                                          (jnp.int32(0), jnp.int32(0)))
        return node, matched

    return jax.vmap(one)(queries, qlens)


def locus_walk_ref(t, cfg, queries, qlens):
    """Synonym-aware locus DP over a batch (kernels/locus_dp.py contract).

    The contract *is* the engine's reference frontier DP on the jnp
    substrate — the kernel must reproduce it bit-for-bit (loci antichains
    and overflow counts), which is what makes the pallas substrate safe to
    swap in under `complete`/`Session`.
    """
    from repro.core.engine import locus
    from repro.core.engine.substrate import get_substrate

    sub = get_substrate("jnp")
    return jax.vmap(
        lambda q, ql: locus.locus_dp(t, cfg, q, ql, sub))(queries, qlens)


def beam_topk_ref(t, cfg, loci, k: int):
    """Beam phase 2 over a locus batch (kernels/beam_topk.py contract).

    The contract *is* the engine's paper-faithful priority search on the
    jnp substrate — the kernel must reproduce it bit-for-bit (scores,
    string ids AND the per-query exact flags, which gate the host-side
    doubled-width retry) for the pallas substrate to be safe to swap in
    under `complete`/`Session`.
    """
    from repro.core.engine import beam

    return jax.vmap(lambda l: beam.beam_topk(t, cfg, l, k))(loci)


def topk_select_ref(scores, payload, k: int):
    """Top-k by score with payload carried along.

    scores: int32/float32[B, C]; payload: int32[B, C].
    Returns (top_scores[B, k], top_payload[B, k]), score-descending,
    ties broken toward lower candidate index.
    """
    top_s, idx = jax.lax.top_k(scores, k)
    return top_s, jnp.take_along_axis(payload, idx, axis=1)


def embedding_bag_ref(table, indices, offsets, weights=None, mode: str = "sum"):
    """torch.nn.EmbeddingBag semantics on a ragged (indices, offsets) batch.

    table: float[V, D]; indices: int32[I] (may contain -1 padding = skip);
    offsets: int32[B+1] bag boundaries; weights: float[I] or None.
    Returns float[B, D].
    """
    V, D = table.shape
    I = indices.shape[0]
    B = offsets.shape[0] - 1
    valid = indices >= 0
    rows = table[jnp.clip(indices, 0, V - 1)]
    if weights is not None:
        rows = rows * weights[:, None]
    rows = jnp.where(valid[:, None], rows, 0.0)
    seg = jnp.searchsorted(offsets[1:], jnp.arange(I), side="right")
    out = jax.ops.segment_sum(rows, seg, num_segments=B)
    if mode == "mean":
        cnt = jax.ops.segment_sum(valid.astype(table.dtype), seg, num_segments=B)
        out = out / jnp.maximum(cnt, 1)[:, None]
    return out


def candidate_topk_ref(query, candidates, k: int):
    """Fused dot-product scoring + top-k over a candidate matrix.

    query: float[D]; candidates: float[C, D].
    Returns (scores[k], ids[k]) by score desc (ties -> lower id).
    """
    s = candidates @ query
    top, idx = jax.lax.top_k(s, k)
    return top, idx.astype(jnp.int32)


def cached_topk_merge_ref(loci, topk_score, topk_sid, k: int):
    """Cached-top-K locus gather + merge (engine phase 2b).

    loci: int32[B, F] locus antichains (-1 = empty slot);
    topk_score/topk_sid: int32[N, K] materialized per-node top-K lists.
    Returns (scores[B, k], sids[B, k]), score-descending, -1 where empty;
    candidates ordered loci-major/K-minor so ties resolve identically to
    the fused kernel.
    """
    valid = loci >= 0
    n = jnp.where(valid, loci, 0)
    sc = jnp.where(valid[..., None], topk_score[n], -1)
    si = jnp.where(valid[..., None], topk_sid[n], -1)
    b = loci.shape[0]
    flat_s = sc.reshape(b, -1)
    flat_i = si.reshape(b, -1)
    top_s, idx = jax.lax.top_k(flat_s, k)
    return top_s, jnp.take_along_axis(flat_i, idx, axis=1)
