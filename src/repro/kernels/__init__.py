"""Pallas TPU kernels (interpret-mode validated on CPU) + jnp references.

- trie_walk:       batched longest-prefix trie descent (paper hot loop)
- topk_select:     fused small-k top-k with payload (merge points)
- embedding_bag:   ragged gather + segment reduce (recsys substrate)
- candidate_topk:  fused dot scoring + running top-k (retrieval / merges)
"""

from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
