"""Pallas TPU kernels (interpret-mode validated on CPU) + jnp references.

- trie_walk:       batched longest-prefix trie descent (rule-free phase 1)
- locus_dp:        fused synonym-aware locus DP (tt/et/ht phase 1 — the
                   paper's rewriting-aware frontier sweep in one kernel)
- beam_topk:       fused beam phase 2 — the generator-pool priority
                   search (pool + result heap in VMEM scratch, masked
                   fixed-trip loop, in-kernel selection network)
- topk_select:     fused small-k top-k with payload (merge points)
- locus_merge:     fused cached-top-K locus gather + merge (phase 2b)
- embedding_bag:   ragged gather + segment reduce (recsys substrate)
- candidate_topk:  fused dot scoring + running top-k (retrieval / merges)

The completion engine reaches these through its ``pallas`` execution
substrate (see :mod:`repro.core.engine.substrate`); ``kernels/ops.py``
holds the padding/interpret-mode wrappers.
"""

from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
