"""HBM -> VMEM DMA streaming helpers for the streamed kernel tier.

The resident Pallas kernels (`trie_walk`, `locus_dp`, `beam_topk`) hold
every table whole in VMEM, which caps per-shard sub-trie size well below
the paper's million-string scale.  The streamed variants keep the tables
in HBM (``memory_space=pltpu.ANY``) and move only what each step touches
into VMEM scratch with double-buffered :func:`pltpu.make_async_copy`:

- :func:`pipelined_dma` — the 2-deep pipeline driver: stage ``j + 1``'s
  copies are started (on the other semaphore slot) before stage ``j`` is
  waited on, so the next transfer is in flight while the current one is
  consumed;
- :class:`StreamTable` — one HBM-resident flat table plus its staging
  buffer; ``windows(starts)`` DMAs the fixed-width slices
  ``[start, start + width)`` for a whole index batch through the
  pipeline and returns them as one VMEM value.

Window legality (every slice in bounds, one window covering a whole CSR
row) is a property of the tile-aligned table layout the builder emits
(``trie_build.pack_stream_tiles``); the static tile widths ride
``EngineConfig``.  On CPU the interpreter emulates the DMAs as copies —
that is the correctness story CI gates on; the overlap only pays off on
real hardware.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def pipelined_dma(n: int, make_dmas) -> None:
    """Run ``n`` DMA stages through a 2-deep double-buffered pipeline.

    ``make_dmas(j, slot)`` returns the list of async copies for stage
    ``j`` parked on semaphore slot ``slot`` (0/1).  Stage ``j + 1`` is
    started on the opposite slot before stage ``j`` is waited on, so at
    any moment one stage is landing while the next is in flight.  Stages
    must write disjoint destinations (each stage owns its staging rows);
    the descriptor is recreated for the wait, which is the documented
    start/wait pattern.  ``n`` must be static.
    """
    if not isinstance(n, int):
        raise TypeError(
            "pipelined_dma: trip count n must be a static Python int "
            f"(got {type(n).__name__}) — a traced count cannot drive "
            "DMA start/wait pairing")
    if n <= 0:
        return

    def start(j, slot):
        for dma in make_dmas(j, slot):
            dma.start()

    def body(j, _):
        @pl.when(j + 1 < n)
        def _():
            start(j + 1, (j + 1) % 2)

        for dma in make_dmas(j, j % 2):
            dma.wait()
        return 0

    start(0, 0)
    jax.lax.fori_loop(0, n, body, 0)


class StreamTable:
    """One HBM-resident table behind windowed double-buffered DMA.

    hbm_ref: the ``memory_space=ANY`` kernel ref of a flat (1-D) or
    row-plane (2-D) table.  buf_ref: VMEM staging scratch with one row
    per pipeline stage — ``[n_stages, width]``; a wider shared buffer may
    be passed, only the leading ``width`` columns of each row are used.
    sem_ref: a ``pltpu.SemaphoreType.DMA((2,))`` slot pair owned by this
    table.  width: the static window width — for CSR tables the stream
    tile from the tile-aligned layout, for row planes the row length.
    """

    def __init__(self, hbm_ref, buf_ref, sem_ref, width: int):
        self.hbm = hbm_ref
        self.buf = buf_ref
        self.sem = sem_ref
        self.width = int(width)
        if self.width <= 0:
            raise ValueError(
                f"StreamTable: window width must be positive, got "
                f"{self.width}")
        if len(hbm_ref.shape) == 1 and self.width & (self.width - 1):
            # flat CSR tables come from pack_stream_tiles, whose tiles
            # are power-of-two so every window stays lane-aligned; row
            # planes (2-D) stream whole rows of arbitrary width
            raise ValueError(
                f"StreamTable: stream tile width must be a power of two "
                f"for 1-D tables, got {self.width} — the tile-aligned "
                f"layout only guarantees window-covers-row for pow2 tiles")
        if int(buf_ref.shape[-1]) < self.width:
            raise ValueError(
                f"StreamTable: staging buffer is narrower than the "
                f"window ({int(buf_ref.shape[-1])} < {self.width}) — "
                f"each DMA would write past its staging row")
        assert hbm_ref.dtype == buf_ref.dtype, (
            f"StreamTable: staging buffer dtype {buf_ref.dtype} does "
            f"not match the HBM table dtype {hbm_ref.dtype} — the "
            f"packed layout's narrow (u8) tables need their own "
            f"staging buffers; widening happens at the read")

    def _dma(self, j, slot, start):
        if len(self.hbm.shape) == 2:              # row plane: whole row
            src = self.hbm.at[start]
        else:
            src = self.hbm.at[pl.ds(start, self.width)]
        return pltpu.make_async_copy(
            src, self.buf.at[j, pl.ds(0, self.width)], self.sem.at[slot])

    def windows(self, starts):
        """Stream the windows ``[starts[i], starts[i] + width)`` (or the
        plane rows ``starts[i]``) into VMEM; returns their values with
        shape ``starts.shape + (width,)``.  Starts must be in bounds —
        callers mask invalid lanes to a safe row (0), exactly as the
        resident gathers do."""
        flat = starts.reshape(-1)
        n = int(flat.shape[0])
        if n > int(self.buf.shape[0]):
            raise ValueError(
                f"StreamTable.windows: {n} DMA stages but only "
                f"{int(self.buf.shape[0])} staging rows — each stage "
                f"must own its own staging row (disjoint destinations)")

        def make(j, slot):
            start = jax.lax.dynamic_index_in_dim(flat, j, keepdims=False)
            return [self._dma(j, slot, start)]

        pipelined_dma(n, make)
        # widen at the read: narrow (u8) staging rows surface as i32, so
        # every in-window compare/select downstream sees the same values
        # the resident gathers see (and -1 sentinels survive jnp.where)
        vals = self.buf[...][:n, : self.width].astype(jnp.int32)
        return vals.reshape(tuple(starts.shape) + (self.width,))

    def gather(self, idx):
        """Element gather ``table[idx]`` via width-1 windows (the 2-D
        row-plane form returns whole rows; use ``windows`` for that)."""
        return self.windows(idx)[..., 0]

    def pairs(self, idx):
        """CSR pointer pairs ``(table[idx], table[idx + 1])`` via one
        width-2 window per lane — the (lo, hi) row bounds of a CSR
        lookup."""
        out = self.windows(idx)
        return out[..., 0], out[..., 1]


# ---------------------------------------------------------------------------
# in-window vector helpers (shared by the streamed kernel bodies)
# ---------------------------------------------------------------------------


def row_take(mat, idx):
    """mat [..., C], idx [..., X] row-local columns -> mat[lane, idx[lane]]
    (a per-lane gather; lane = every leading axis of ``mat``)."""
    c = int(mat.shape[-1])
    flat_m = mat.reshape((-1, c))
    flat_i = idx.reshape((flat_m.shape[0], -1))
    r = jax.lax.broadcasted_iota(jnp.int32, flat_i.shape, 0)
    out = jnp.take(flat_m.reshape(-1), r * c + flat_i)
    return out.reshape(idx.shape)


def stream_csr_children(ptr_tab: StreamTable, char_tab: StreamTable,
                        child_tab: StreamTable, nodes, ch, iters: int):
    """Streamed CSR child lookup: ``children[nodes]`` labelled ``ch``
    (-1 propagated/absent), with the row bounds and row content DMA'd
    from HBM instead of read from VMEM-resident tables.

    ``ptr_tab`` streams the (lo, hi) pointer pairs, ``char_tab`` /
    ``child_tab`` the ``[lo, lo + tile)`` row windows — the tile-aligned
    layout guarantees one window covers the whole row, so the in-window
    lower bound probes exactly the content a global binary search over
    ``[lo, hi)`` would, making the result bit-identical to
    ``primitives.csr_child_lookup`` and the resident kernels' forms.
    ``ch`` broadcasts against ``nodes``.
    """
    valid = nodes >= 0
    chb = jnp.broadcast_to(ch, nodes.shape)
    nn = jnp.where(valid, nodes, 0)
    lo, hi = ptr_tab.pairs(nn)
    span = hi - lo
    wc = char_tab.windows(lo)
    wk = child_tab.windows(lo)
    w = int(wc.shape[-1])
    pos = window_lower_bound(wc, span, chb, iters)
    posc = jnp.clip(pos, 0, w - 1)
    found = (pos < span) & \
        (row_take(wc, posc[..., None])[..., 0] == chb) & valid & (chb >= 0)
    child = row_take(wk, posc[..., None])[..., 0]
    return jnp.where(found, child, -1)


def window_lower_bound(win, count, x, iters: int):
    """Row-local lower bound: first column ``p`` in ``[0, count)`` with
    ``win[..., p] >= x`` (fixed ``iters`` trips).  ``win`` [..., W] holds
    a sorted CSR row per lane; ``count``/``x`` broadcast against the lane
    shape.  Identical to a global lower bound over ``[lo, lo + count)``
    of the backing table — the probed content is the same row."""
    w = int(win.shape[-1])
    lo = jnp.zeros_like(count)
    hi = count
    for _ in range(iters):
        cont = lo < hi
        mid = (lo + hi) >> 1
        v = row_take(win, jnp.clip(mid, 0, w - 1)[..., None])[..., 0]
        go_right = v < x
        lo = jnp.where(cont & go_right, mid + 1, lo)
        hi = jnp.where(cont & ~go_right, mid, hi)
    return lo
