"""Pallas TPU kernel: batched longest-prefix trie walk.

The hot inner loop of every completion lookup (paper Alg. 2/4 locus search).
Queries are blocked into VMEM tiles of (BQ, L); the CSR tables
(first_child / edge_char / edge_child) are VMEM-resident — the sharding
story of the distributed index (§DESIGN 2.5) keeps per-shard sub-tries
small enough for this. Each of the L steps performs a vectorized
binary search over each query's current CSR row (fixed `iters` rounds,
no data-dependent control flow).

TPU adaptation notes: on a CPU/GPU this is pointer chasing; here it is a
fixed-depth loop of vector gathers (dynamic VMEM loads), which the VPU
executes without divergence.

Two tiers share the walk semantics:

- the *resident* kernel (``trie_walk``) holds the CSR tables whole in
  VMEM — fastest when the per-shard sub-trie fits the budget;
- the *streamed* kernel (``trie_walk_streamed``) keeps the tables in HBM
  and, per step, double-buffers each query's pointer pair and child-row
  window into VMEM scratch via ``make_async_copy``
  (:mod:`repro.kernels.stream`), so shard size is no longer VMEM-bound.
  The tile-aligned layout (``trie_build.pack_stream_tiles``) guarantees
  one window covers any node's whole child row, which makes the
  in-window binary search probe exactly what the resident kernel's
  global search probes — results are bit-identical.

``PallasSubstrate.can_walk_batch`` picks the tier by comparing the table
bytes against the configured VMEM budget.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.stream import StreamTable, stream_csr_children


def _kernel(fc_ref, ec_ref, echild_ref, q_ref, qlen_ref, node_ref, depth_ref,
            *, iters: int, seq_len: int):
    fc = fc_ref[...]
    ec = ec_ref[...]
    echild = echild_ref[...]
    q = q_ref[...]
    qlen = qlen_ref[...]
    bq = q.shape[0]
    e = ec.shape[0]

    def step(i, carry):
        node, matched = carry
        c = q[:, i]
        lo = jnp.take(fc, node)
        hi = jnp.take(fc, node + 1)
        for _ in range(iters):  # branch-free binary search (lower bound)
            cont = lo < hi
            mid = (lo + hi) >> 1
            v = jnp.take(ec, jnp.clip(mid, 0, e - 1))
            go_right = v < c
            lo = jnp.where(cont & go_right, mid + 1, lo)
            hi = jnp.where(cont & ~go_right, mid, hi)
        pos = jnp.clip(lo, 0, e - 1)
        found = (lo < jnp.take(fc, node + 1)) & (jnp.take(ec, pos) == c)
        active = (matched == i) & (i < qlen) & (c >= 0)
        take = found & active
        node = jnp.where(take, jnp.take(echild, pos), node)
        matched = jnp.where(take, matched + 1, matched)
        return node, matched

    node0 = jnp.zeros((bq,), jnp.int32)
    node, matched = jax.lax.fori_loop(0, seq_len, step, (node0, node0))
    node_ref[...] = node
    depth_ref[...] = matched


@functools.partial(jax.jit, static_argnames=("block_q", "interpret"))
def trie_walk(first_child, edge_char, edge_child, queries, qlens,
              *, block_q: int = 128, interpret: bool = True):
    """Deepest locus node + matched depth for each query.

    queries: int32[B, L] (-1 padded), B divisible by block_q (wrapper in
    ops.py pads). Returns (node[B], depth[B]).
    """
    bsz, seq_len = queries.shape
    n1 = first_child.shape[0]
    e = max(edge_char.shape[0], 1)
    iters = max(1, (e).bit_length())
    if edge_char.shape[0] == 0:
        return jnp.zeros((bsz,), jnp.int32), jnp.zeros((bsz,), jnp.int32)
    grid = (bsz // block_q,)
    kernel = functools.partial(_kernel, iters=iters, seq_len=seq_len)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n1,), lambda i: (0,)),
            pl.BlockSpec((e,), lambda i: (0,)),
            pl.BlockSpec((e,), lambda i: (0,)),
            pl.BlockSpec((block_q, seq_len), lambda i: (i, 0)),
            pl.BlockSpec((block_q,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block_q,), lambda i: (i,)),
            pl.BlockSpec((block_q,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz,), jnp.int32),
            jax.ShapeDtypeStruct((bsz,), jnp.int32),
        ],
        interpret=interpret,
    )(first_child, edge_char, edge_child, queries, qlens)


def _stream_kernel(fc_hbm, ec_hbm, echild_hbm, q_ref, qlen_ref,
                   node_ref, depth_ref,
                   pair_buf, wc_buf, wk_buf, sem_p, sem_c, sem_k, *,
                   tile: int, iters: int, seq_len: int):
    q = q_ref[...]
    qlen = qlen_ref[...]
    bq = q.shape[0]
    fc_t = StreamTable(fc_hbm, pair_buf, sem_p, 2)
    ec_t = StreamTable(ec_hbm, wc_buf, sem_c, tile)
    ek_t = StreamTable(echild_hbm, wk_buf, sem_k, tile)

    def step(i, carry):
        node, matched = carry
        c = q[:, i]
        # nodes are always live (the walk starts at the root and only
        # ever descends), so the child lookup needs no -1 masking; the
        # ch >= 0 guard inside matches the resident kernel's `active`
        child = stream_csr_children(fc_t, ec_t, ek_t, node, c, iters)
        take = (child >= 0) & (matched == i) & (i < qlen)
        node = jnp.where(take, child, node)
        matched = jnp.where(take, matched + 1, matched)
        return node, matched

    node0 = jnp.zeros((bq,), jnp.int32)
    node, matched = jax.lax.fori_loop(0, seq_len, step, (node0, node0))
    node_ref[...] = node
    depth_ref[...] = matched


@functools.partial(jax.jit, static_argnames=("tile", "block_q", "interpret"))
def trie_walk_streamed(first_child, edge_char, edge_child, queries, qlens,
                       *, tile: int, block_q: int = 8,
                       interpret: bool = True):
    """HBM-resident variant of :func:`trie_walk`: same contract, same
    results, but the CSR tables stay in HBM and each step's pointer pairs
    and child-row windows are DMA-streamed into VMEM scratch.  ``tile``
    is the static window width from the tile-aligned layout
    (``EngineConfig.walk_tile``)."""
    bsz, seq_len = queries.shape
    if edge_char.shape[0] == 0:
        return jnp.zeros((bsz,), jnp.int32), jnp.zeros((bsz,), jnp.int32)
    iters = max(1, tile.bit_length())
    grid = (bsz // block_q,)
    kernel = functools.partial(_stream_kernel, tile=tile, iters=iters,
                               seq_len=seq_len)
    hbm = pl.BlockSpec(memory_space=pltpu.ANY)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[hbm, hbm, hbm,
                  pl.BlockSpec((block_q, seq_len), lambda i: (i, 0)),
                  pl.BlockSpec((block_q,), lambda i: (i,))],
        out_specs=[
            pl.BlockSpec((block_q,), lambda i: (i,)),
            pl.BlockSpec((block_q,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz,), jnp.int32),
            jax.ShapeDtypeStruct((bsz,), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, 2), jnp.int32),     # pointer-pair stage
            pltpu.VMEM((block_q, tile), jnp.int32),  # char-row windows
            pltpu.VMEM((block_q, tile), jnp.int32),  # child-row windows
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
        ],
        interpret=interpret,
    )(first_child, edge_char, edge_child, queries, qlens)
